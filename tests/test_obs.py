"""Observability-layer suite (repro.obs, DESIGN.md §14).

Three tiers:

* unit — event-bus ordering/boundedness, trace-export round-trips
  (JSON-lines <-> Chrome ``trace_event``), metrics instruments (exact and
  decimated-histogram regimes), monitor verdicts on handcrafted round
  views, profiler hook windows (jax.profiler monkeypatched);
* integration — a real reduced-model batcher run with FULL observability
  (strict monitors, live registry, flusher, trace retention): exports
  round-trip, the registry agrees with ``report()``, strict monitors stay
  silent, and an injected ledger corruption raises at the very next
  round naming the offending request;
* golden parity — the fixture workloads (two-lane, three-lane, every
  policy) re-run with strict observability enabled must stay BIT-
  IDENTICAL to tests/fixtures/golden_serving.json at H=1 and H=8 (and
  under a mesh): watching the run must never change it.
"""
import json

import numpy as np
import pytest

from repro.obs import (
    CAT_COMPILE,
    CAT_MONITOR,
    CAT_REQUEST,
    CAT_ROUND,
    CapacityMonitor,
    Counter,
    EventBus,
    Histogram,
    KIND_SPAN,
    LaneLadderMonitor,
    LaneView,
    LedgerConservationMonitor,
    MetricsFlusher,
    MetricsRegistry,
    MonitorSuite,
    MonitorViolation,
    ObsConfig,
    ProfilerHooks,
    RoundView,
    read_jsonl,
    to_chrome,
    write_chrome,
    write_jsonl,
)


class FakeClock:
    def __init__(self, tick=0.25):
        self.t = 0.0
        self.tick = tick

    def __call__(self):
        self.t += self.tick
        return self.t


# -- event bus ----------------------------------------------------------------


def test_bus_ordering_and_timestamps():
    bus = EventBus(clock=FakeClock(0.25))
    a = bus.publish("submit", cat=CAT_REQUEST, rid=0)
    b = bus.publish("round", cat=CAT_ROUND, kind=KIND_SPAN, dur=0.1, step=0)
    c = bus.publish("complete", cat=CAT_REQUEST, rid=0)
    assert [e.seq for e in bus.events()] == [0, 1, 2]
    assert (a.ts, b.ts, c.ts) == (0.25, 0.5, 0.75)
    assert bus.published == 3 and len(bus) == 3 and bus.dropped == 0
    assert b.args["step"] == 0 and b.dur == 0.1


def test_bus_boundedness_evicts_oldest_but_delivers_all():
    seen = []
    bus = EventBus(capacity=4, clock=FakeClock())
    bus.subscribe(lambda e: seen.append(e.seq))
    for i in range(10):
        bus.publish("round", step=i)
    # retention is bounded: the ring holds the 4 newest...
    assert [e.args["step"] for e in bus.events()] == [6, 7, 8, 9]
    assert bus.dropped == 6 and bus.published == 10
    # ...but delivery is not: the subscriber saw every event, in order
    assert seen == list(range(10))


def test_bus_explicit_ts_bypasses_clock():
    clock = FakeClock()
    bus = EventBus(clock=clock)
    ev = bus.publish("round", ts=123.5)
    assert ev.ts == 123.5 and clock.t == 0.0


def test_bus_counts_by_name():
    bus = EventBus(clock=FakeClock())
    for name in ("submit", "round", "round", "complete"):
        bus.publish(name)
    assert bus.counts_by_name() == {"submit": 1, "round": 2, "complete": 1}


# -- trace export -------------------------------------------------------------


def _sample_events():
    bus = EventBus(clock=FakeClock(0.5))
    bus.publish("submit", cat=CAT_REQUEST, rid=0, prompt_len=4, guided=True)
    bus.publish(
        "round", cat=CAT_ROUND, kind=KIND_SPAN, dur=0.2, step=0,
        guided_active=np.int64(1), nfes_expected=np.float32(2.0),
    )
    bus.publish("compile", cat=CAT_COMPILE, lane="guided", bucket=2, dt_s=1.5)
    return bus.events()


def test_jsonl_round_trip_exact(tmp_path):
    events = _sample_events()
    path = str(tmp_path / "trace.jsonl")
    write_jsonl(events, path)
    back = read_jsonl(path)
    assert len(back) == len(events)
    for orig, rt in zip(events, back):
        assert rt.seq == orig.seq and rt.ts == orig.ts
        assert rt.name == orig.name and rt.cat == orig.cat
        assert rt.kind == orig.kind and rt.dur == orig.dur
        # numpy scalars land as plain JSON numbers, values preserved
        assert rt.args == json.loads(json.dumps(rt.args))
        for k, v in orig.args.items():
            assert rt.args[k] == v


def test_chrome_trace_structure(tmp_path):
    events = _sample_events()
    doc = to_chrome(events)
    tes = doc["traceEvents"]
    # one process_name + one thread_name per category present
    metas = [e for e in tes if e["ph"] == "M"]
    assert {m["args"]["name"] for m in metas} >= {
        "repro-serving", "request", "round", "compile",
    }
    spans = [e for e in tes if e["ph"] == "X"]
    assert len(spans) == 1
    span = spans[0]
    # Event.ts is the END of a span; Chrome wants the start, in us,
    # rebased to the earliest start in the stream
    starts = [e.ts - e.dur for e in events]
    base = min(starts)
    assert span["ts"] == pytest.approx((1.0 - 0.2 - base) * 1e6)
    assert span["dur"] == pytest.approx(0.2 * 1e6)
    # distinct categories get distinct tids (separate Perfetto tracks)
    tids = {e["cat"]: e["tid"] for e in tes if e["ph"] in ("X", "i")}
    assert len(set(tids.values())) == len(tids)
    path = str(tmp_path / "trace.json")
    write_chrome(events, path)
    assert json.load(open(path))["traceEvents"] == tes


def test_chrome_counter_and_instant_phases(tmp_path):
    from repro.obs import KIND_COUNTER

    bus = EventBus(clock=FakeClock(1.0))
    bus.publish("lane.occupancy", kind=KIND_COUNTER, guided=2, cond=1)
    bus.publish("violation", cat=CAT_MONITOR, rid=3)
    tes = to_chrome(bus.events())["traceEvents"]
    counters = [e for e in tes if e["ph"] == "C"]
    assert len(counters) == 1
    assert counters[0]["args"] == {"guided": 2, "cond": 1}
    instants = [e for e in tes if e["ph"] == "i"]
    assert len(instants) == 1 and instants[0]["s"] == "t"


def test_jsonl_rejects_non_serializable_args(tmp_path):
    bus = EventBus(clock=FakeClock())
    bus.publish("round", payload=object())
    with pytest.raises(TypeError, match="not JSON-serializable"):
        write_jsonl(bus.events(), str(tmp_path / "bad.jsonl"))


# -- metrics ------------------------------------------------------------------


def test_counter_is_monotone():
    c = Counter()
    c.inc(2.5)
    c.inc()
    assert c.value == 3.5
    with pytest.raises(AssertionError):
        c.inc(-1.0)


def test_histogram_exact_percentiles():
    h = Histogram()
    for v in (10.0, 20.0, 30.0, 40.0):
        h.observe(v)
    assert h.exact
    snap = h.snapshot()
    assert snap["count"] == 4 and snap["sum"] == 100.0
    assert snap["min"] == 10.0 and snap["max"] == 40.0
    assert snap["p50"] == pytest.approx(25.0)
    assert snap["p90"] == pytest.approx(37.0)
    assert snap["p99"] == pytest.approx(39.7)


def test_histogram_decimation_is_deterministic_and_bounded():
    rng = np.random.default_rng(7)
    vals = rng.normal(100.0, 15.0, size=3000)
    h1, h2 = Histogram(max_samples=256), Histogram(max_samples=256)
    for v in vals:
        h1.observe(float(v))
        h2.observe(float(v))
    assert not h1.exact and h1.weight > 1
    assert len(h1._samples) <= 256
    # identical streams -> identical decimated state (no RNG in the path)
    assert h1.snapshot() == h2.snapshot()
    # count/sum/min/max stay exact through decimation
    assert h1.count == 3000
    assert h1.sum == pytest.approx(float(np.sum(vals)))
    assert h1.min == float(np.min(vals)) and h1.max == float(np.max(vals))
    # quantiles stay near the exact ones (~1/n error)
    assert h1.percentile(50) == pytest.approx(
        float(np.percentile(vals, 50)), rel=0.05
    )


def test_registry_snapshot_and_flusher(tmp_path):
    reg = MetricsRegistry()
    reg.counter("tokens.out").inc(5)
    reg.gauge("lane.guided.active").set(2)
    reg.histogram("step_latency_ms").observe(12.0)
    snap = reg.snapshot()
    assert snap["counters"] == {"tokens.out": 5.0}
    assert snap["gauges"] == {"lane.guided.active": 2.0}
    assert snap["histograms"]["step_latency_ms"]["count"] == 1
    json.dumps(snap)  # JSON-able end to end

    path = str(tmp_path / "metrics.json")
    flusher = MetricsFlusher(reg, path, every=2)
    bus = EventBus(clock=FakeClock())
    bus.subscribe(flusher)
    for i in range(5):
        bus.publish("round", step=i)
        bus.publish("submit")  # non-round events must not advance cadence
    assert flusher.flushes == 2  # rounds 2 and 4
    flusher.flush()  # final state
    assert json.load(open(path)) == reg.snapshot()


# -- monitors -----------------------------------------------------------------


def _view(**over):
    base = dict(
        step=5,
        lanes={
            "guided": LaneView(active=1, capacity=2, rids=(7, None)),
            "linear": LaneView(active=0, capacity=0, rids=()),
            "cond": LaneView(active=1, capacity=1, rids=(3,)),
        },
        buckets=(1, 2),
        max_slots=2,
        nfes_device={7: 4.0, 3: 6.0},
        nfes_expected={7: 4.0, 3: 6.0},
        lane_history={7: ("guided",), 3: ("guided", "cond")},
    )
    base.update(over)
    return RoundView(**base)


def test_ledger_monitor_clean_and_corrupted():
    mon = LedgerConservationMonitor()
    assert mon.check(_view()) == []
    out = mon.check(_view(nfes_device={7: 5.0, 3: 6.0}))
    assert len(out) == 1
    v = out[0]
    assert v["monitor"] == "ledger" and v["rid"] == 7
    assert v["lane"] == "guided" and v["slot"] == 0 and v["step"] == 5
    assert "5.0 != expected 4.0" in v["message"]


def test_ledger_monitor_flags_decrease():
    mon = LedgerConservationMonitor()
    assert mon.check(_view()) == []
    out = mon.check(_view(nfes_device={7: 3.0, 3: 6.0},
                          nfes_expected={7: 3.0, 3: 6.0}))
    assert len(out) == 1 and "decreased" in out[0]["message"]


def test_ladder_monitor_flags_backward_walk_and_residency():
    mon = LaneLadderMonitor()
    assert mon.check(_view()) == []
    out = mon.check(_view(lane_history={7: ("guided",),
                                        3: ("cond", "guided")}))
    assert any("non-monotone" in v["message"] for v in out)
    # resident lane must be the history's last entry
    out = mon.check(_view(lane_history={7: ("guided", "cond"),
                                        3: ("guided", "cond")}))
    assert any(v["rid"] == 7 and "resident" in v["message"] for v in out)


def test_capacity_monitor_flags_double_residency_and_overflow():
    mon = CapacityMonitor()
    assert mon.check(_view()) == []
    out = mon.check(_view(lanes={
        "guided": LaneView(active=2, capacity=2, rids=(7, 3)),
        "linear": LaneView(active=0, capacity=0, rids=()),
        "cond": LaneView(active=1, capacity=1, rids=(3,)),
    }))
    assert any("two lanes" in v["message"] for v in out)
    assert any("total active" in v["message"] for v in out)


def test_capacity_monitor_flags_bookkeeping_drift():
    mon = CapacityMonitor()
    out = mon.check(_view(lanes={
        # slot map shorter than capacity, reported active over-counted
        "guided": LaneView(active=2, capacity=2, rids=(7,)),
        # non-bucket capacity
        "linear": LaneView(active=0, capacity=3, rids=(None, None, None)),
        "cond": LaneView(active=1, capacity=1, rids=(3,)),
    }))
    msgs = [v["message"] for v in out]
    assert any("slot map length" in m for m in msgs)
    assert any("reported active" in m for m in msgs)
    assert any("not a bucket" in m for m in msgs)


def test_monitor_suite_strict_raises_and_records():
    bus = EventBus(clock=FakeClock())
    reg = MetricsRegistry()
    suite = MonitorSuite(strict=False, bus=bus, registry=reg)
    assert suite.on_round(_view()) == []
    bad = _view(nfes_device={7: 5.0, 3: 6.0})
    found = suite.on_round(bad)
    assert len(found) == 1 and suite.violations == found
    assert reg.counters["monitor.rounds_checked"].value == 2
    assert reg.counters["monitor.violations"].value == 1
    assert [e.name for e in bus.events() if e.cat == CAT_MONITOR] == ["violation"]

    strict = MonitorSuite(strict=True)
    with pytest.raises(MonitorViolation) as exc:
        strict.on_round(bad)
    assert exc.value.violations[0]["rid"] == 7
    assert "request 7" in str(exc.value)


# -- profiler hooks -----------------------------------------------------------


@pytest.fixture
def fake_profiler(monkeypatch):
    calls = []
    import jax.profiler

    monkeypatch.setattr(
        jax.profiler, "start_trace", lambda d: calls.append(("start", d))
    )
    monkeypatch.setattr(
        jax.profiler, "stop_trace", lambda: calls.append(("stop", None))
    )
    return calls


def test_profiler_window_opens_and_closes(fake_profiler, tmp_path):
    bus = EventBus(clock=FakeClock())
    hooks = ProfilerHooks(str(tmp_path), start_round=2, num_rounds=3, bus=bus)
    for i in range(10):
        hooks.on_round(i)
    assert fake_profiler == [("start", str(tmp_path)), ("stop", None)]
    names = [e.name for e in bus.events()]
    assert names == ["profile.start", "profile.stop"]
    assert bus.events()[0].args["round"] == 2
    assert bus.events()[1].args["round"] == 5
    assert hooks.captured and not hooks.active and hooks.error is None


def test_profiler_disabled_and_close(fake_profiler, tmp_path):
    hooks = ProfilerHooks(None, start_round=0)
    for i in range(5):
        hooks.on_round(i)
    assert fake_profiler == []  # no dir -> no-op
    hooks = ProfilerHooks(str(tmp_path), start_round=0, num_rounds=100)
    hooks.on_round(0)
    hooks.close()  # run ended inside the window
    assert fake_profiler == [("start", str(tmp_path)), ("stop", None)]


def test_profiler_failure_never_raises(monkeypatch):
    import jax.profiler

    def boom(_):
        raise RuntimeError("already tracing")

    monkeypatch.setattr(jax.profiler, "start_trace", boom)
    bus = EventBus(clock=FakeClock())
    hooks = ProfilerHooks("/tmp/nowhere", start_round=0, bus=bus)
    for i in range(5):
        hooks.on_round(i)  # must not raise, must not retry every round
    assert hooks.error and "already tracing" in hooks.error
    assert [e.name for e in bus.events()] == ["profile.error"]


def test_obs_config_validation():
    with pytest.raises(ValueError):
        ObsConfig(bus_capacity=0)
    with pytest.raises(ValueError):
        ObsConfig(profile_rounds=0)


# -- integration: a real batcher run under full observability -----------------


@pytest.fixture(scope="module")
def obs_run():
    from tests.make_golden import _prompts, golden_model
    from repro.serving import (
        BatcherConfig, EngineConfig, Request, StepBatcher,
    )

    cfg, api, params = golden_model()
    p = _prompts(31, [6, 5, 4])
    reqs = [
        Request(prompt=p[0], max_new_tokens=7),
        Request(prompt=p[1], max_new_tokens=5),
        Request(prompt=p[2], max_new_tokens=4, guided=False),
    ]
    ec = EngineConfig(scale=1.5, gamma_bar=0.0, max_batch=2)
    bat = StepBatcher(
        api, params, ec, BatcherConfig(max_slots=2, buckets=(1, 2)),
        obs=ObsConfig(strict=True),
    )
    for r, a in zip(reqs, [0, 0, 2]):
        bat.submit(r, arrival_step=a)
    done = bat.run()
    return bat, done


def test_obs_run_strict_monitors_silent(obs_run):
    bat, done = obs_run
    assert len(done) == 3
    rep = bat.report()
    assert rep["monitors"]["rounds_checked"] > 0
    assert rep["monitors"]["violations"] == []
    assert rep["totals"]["nfes_device"] == rep["totals"]["nfes_expected"]


def test_obs_run_event_stream_shape(obs_run):
    bat, done = obs_run
    counts = bat.bus.counts_by_name()
    assert counts["submit"] == 3 and counts["admit"] == 3
    assert counts["complete"] == 3
    assert counts["round"] == bat.telemetry.report()["totals"]["decode_steps"]
    assert counts["compile"] >= 1  # lane + prefill attribution
    # per-event ordering: every request's lifecycle is causally ordered
    seqs = {}
    for ev in bat.bus.events():
        if ev.cat == CAT_REQUEST:
            seqs.setdefault(ev.args["rid"], []).append(ev.name)
    for rid, names in seqs.items():
        assert names.index("submit") < names.index("admit") < names.index(
            "complete"
        ), (rid, names)


def test_obs_run_trace_round_trip(obs_run, tmp_path):
    bat, _ = obs_run
    events = bat.bus.events()
    jsonl = str(tmp_path / "trace.jsonl")
    write_jsonl(events, jsonl)
    back = read_jsonl(jsonl)
    assert [e.seq for e in back] == [e.seq for e in events]
    assert [e.name for e in back] == [e.name for e in events]
    doc = to_chrome(back)
    spans = [e for e in doc["traceEvents"] if e["ph"] == "X"]
    assert len(spans) == sum(1 for e in events if e.kind == KIND_SPAN)
    assert all(s["ts"] >= 0 for s in spans)  # rebased to the stream start


def test_obs_run_registry_agrees_with_report(obs_run):
    """The live registry and report() fold the same stream: totals must
    agree, and the steady-state latency percentiles must be EQUAL (both
    are np.percentile over the identical non-warmup samples while the
    histogram is in its exact regime)."""
    bat, _ = obs_run
    t = bat.report()["totals"]
    snap = bat.telemetry.registry.snapshot()
    c = snap["counters"]
    assert c["tokens.out"] == t["tokens_out"]
    assert c["nfes.device"] == pytest.approx(t["nfes_device"])
    assert c["nfes.expected"] == pytest.approx(t["nfes_expected"])
    assert c["rounds"] == t["decode_steps"]
    assert c["device.dispatches"] == t["device_dispatches"]
    assert c.get("rounds.warmup", 0.0) == t["warmup_steps"]
    assert c.get("compile.round_s", 0.0) == pytest.approx(t["compile_s"])
    assert c["monitor.rounds_checked"] == bat.monitors.rounds_checked
    hist = bat.telemetry.registry.histograms["step_latency_ms"]
    assert hist.exact
    assert hist.count == t["decode_steps"] - t["warmup_steps"]
    for q, key in ((50, "p50"), (90, "p90"), (99, "p99")):
        assert hist.percentile(q) == pytest.approx(t["step_latency_ms"][key])
    tt = snap["histograms"]["request.ttft_ms"]
    assert tt["count"] == 3
    assert tt["p50"] == pytest.approx(t["ttft_ms"]["p50"])


def test_strict_monitor_raises_on_injected_ledger_corruption():
    """Corrupt the host's device-ledger mirror mid-run: the very next
    round's conservation check must raise, naming the corrupted rid."""
    from tests.make_golden import _prompts, golden_model
    from repro.serving import (
        BatcherConfig, EngineConfig, Request, StepBatcher,
    )

    cfg, api, params = golden_model()
    p = _prompts(32, [6, 5])
    ec = EngineConfig(scale=1.5, gamma_bar=0.0, max_batch=2)
    bat = StepBatcher(
        api, params, ec, BatcherConfig(max_slots=2, buckets=(1, 2)),
        obs=ObsConfig(strict=True),
    )
    rid = bat.submit(Request(prompt=p[0], max_new_tokens=8))
    bat.submit(Request(prompt=p[1], max_new_tokens=8))
    for _ in range(3):
        assert bat.step()
    # corrupt the accumulated expectation (the device mirror is re-read
    # from the fetched ledger every round, so it self-heals; the priced
    # expectation is folded incrementally and carries the fault forward)
    bat._expected_rid[rid] += 1.0
    with pytest.raises(MonitorViolation) as exc:
        bat.step()
    v = exc.value.violations[0]
    assert v["monitor"] == "ledger" and v["rid"] == rid
    assert f"request {rid}" in str(exc.value)
    # non-strict mode records the same violation instead of raising
    bat2 = StepBatcher(
        api, params, ec, BatcherConfig(max_slots=2, buckets=(1, 2)),
        obs=ObsConfig(strict=False),
    )
    rid2 = bat2.submit(Request(prompt=p[0], max_new_tokens=8))
    bat2.submit(Request(prompt=p[1], max_new_tokens=8))
    for _ in range(3):
        bat2.step()
    bat2._expected_rid[rid2] += 1.0
    bat2.step()
    assert any(v["rid"] == rid2 for v in bat2.monitors.violations)
    assert bat2.telemetry.registry.counters["monitor.violations"].value >= 1


def test_monitors_can_be_disabled():
    from tests.make_golden import _prompts, golden_model
    from repro.serving import (
        BatcherConfig, EngineConfig, Request, StepBatcher,
    )

    cfg, api, params = golden_model()
    p = _prompts(33, [5])
    ec = EngineConfig(scale=1.5, gamma_bar=0.0, max_batch=1)
    bat = StepBatcher(
        api, params, ec, BatcherConfig(max_slots=1, buckets=(1,)),
        obs=ObsConfig(monitors=False),
    )
    bat.submit(Request(prompt=p[0], max_new_tokens=4))
    bat.run()
    assert bat.monitors is None
    assert "monitors" not in bat.report()


# -- golden parity: observability must never perturb the run ------------------


@pytest.fixture(scope="module")
def golden():
    from tests.make_golden import FIXTURE

    with open(FIXTURE) as f:
        return json.load(f)


def _check_fixture_requests(got, want):
    from tests.test_golden import _diff_requests

    diff = _diff_requests(got, want)
    assert not diff, "obs perturbed the run:\n  " + "\n  ".join(diff)


def _check_tokens_and_ledgers(got, want):
    """Horizon runs vs the H=1 fixture: tokens and NFE ledgers must match
    bit-exactly; lifecycle steps legitimately quantize to horizon
    boundaries (tests/test_horizon.py), so they are compared separately
    against an obs-off run at the same horizon."""
    assert set(got) == set(want)
    for rid in sorted(got):
        assert got[rid]["tokens"] == want[rid]["tokens"], f"request {rid}"
        assert got[rid]["nfes"] == want[rid]["nfes"], f"request {rid}"


@pytest.mark.parametrize("horizon", [1, 8])
def test_golden_two_lane_bit_identical_with_strict_obs(golden, horizon):
    from tests.make_golden import run_batcher_case

    got = run_batcher_case(horizon=horizon, obs=ObsConfig(strict=True))
    if horizon == 1:
        _check_fixture_requests(got["requests"], golden["batcher"]["requests"])
    else:
        _check_tokens_and_ledgers(
            got["requests"], golden["batcher"]["requests"]
        )
        base = run_batcher_case(horizon=horizon)
        _check_fixture_requests(got["requests"], base["requests"])


@pytest.mark.parametrize("policy", ["default", "compress", "online_ag"])
@pytest.mark.parametrize("horizon", [1, 8])
def test_golden_policies_bit_identical_with_strict_obs(golden, policy, horizon):
    from tests.make_golden import run_policy_case

    got = run_policy_case(policy, horizon=horizon, obs=ObsConfig(strict=True))
    want = golden["policies"][policy]
    assert got["nfes_device"] == want["nfes_device"]
    if horizon == 1:
        _check_fixture_requests(got["requests"], want["requests"])
    else:
        _check_tokens_and_ledgers(got["requests"], want["requests"])
        base = run_policy_case(policy, horizon=horizon)
        _check_fixture_requests(got["requests"], base["requests"])


def test_golden_three_lane_bit_identical_with_strict_obs(golden):
    from repro.core.linear_ag import WindowCoeffs
    from tests.make_golden import run_three_lane_case

    coeffs = WindowCoeffs(
        K=int(golden["coeffs"]["K"]),
        beta=np.asarray(golden["coeffs"]["beta"], np.float32),
    )
    got = run_three_lane_case(coeffs, obs=ObsConfig(strict=True))
    _check_fixture_requests(got["requests"], golden["three_lane"]["requests"])
    assert got["nfes_device"] == golden["three_lane"]["nfes_device"]


def test_golden_two_lane_bit_identical_with_strict_obs_on_mesh(golden):
    """Strict observability composes with sharded serving: the (d, m)
    mesh run stays locked to the meshless fixture.  Shapes derive from
    the visible device count ((1, 1) under tier-1; the CI obs job forces
    8 simulated devices and checks (8, 1))."""
    import jax

    from repro.launch.mesh import make_host_mesh
    from tests.make_golden import run_batcher_case

    shape = (jax.device_count(), 1)
    mesh = make_host_mesh(shape)
    got = run_batcher_case(mesh=mesh, obs=ObsConfig(strict=True))
    _check_fixture_requests(got["requests"], golden["batcher"]["requests"])
