"""Registry-parametrized correctness net for guidance policies.

Every policy registered in ``repro.core.policies`` is run through the same
harness (``tests/_toy_lm.run_policy_case``): batched serving must conserve
the NFE ledger (device == host mirror == per-request sum), walk the
policy's own lane graph monotonically, compile once per (lane, bucket),
and reproduce the eager B=1 oracle (``policy_generate``) token- and
ledger-exactly.  A future policy registered in ``core/policies.py`` gets
this net for free via ``pytest.mark.parametrize`` over the registry.

The hypothesis section generalizes the lane-ladder churn property to
random per-request policies (admission order, budgets, EOS) — ledger
conservation and no KV bleed (H=1 == H=4 token parity) must survive any
interleaving.
"""
import dataclasses
import os

import numpy as np
import pytest

from repro.core.policies import (
    PSTATE_SPECS,
    CompressGuidance,
    get_policy,
    policy_names,
    registered_policies,
)
from repro.serving import EngineConfig, Request, policy_generate
from tests._toy_lm import VOCAB, run_policy_case, toy_serving

POLICIES = list(policy_names())


def _reqs(seed, policy, budgets=(12, 8, 6), gbars=(None, 2.0, None)):
    rng = np.random.default_rng(seed)
    return [
        Request(
            prompt=rng.integers(1, VOCAB, size=4 + i).astype(np.int32),
            max_new_tokens=b, gamma_bar=g, policy=policy,
        )
        for i, (b, g) in enumerate(zip(budgets, gbars))
    ]


# -- registry surface ---------------------------------------------------------


def test_registry_exposes_default_compress_online():
    assert POLICIES[0] == "default"
    assert set(POLICIES) >= {"default", "compress", "online_ag"}
    assert get_policy("compress").name == "compress"
    with pytest.raises(KeyError):
        get_policy("no-such-policy")


def test_policy_state_specs_consistent():
    """Every policy's state leaves must exist in PSTATE_SPECS, and the
    sharding rules in partition.py must cover exactly those leaves (the
    dict is duplicated there to avoid an import cycle)."""
    from repro.sharding.partition import PSTATE_KEY_AXES

    assert set(PSTATE_KEY_AXES) == set(PSTATE_SPECS)
    for pol in registered_policies():
        assert set(pol.state_keys) <= set(PSTATE_SPECS), pol.name
        assert tuple(pol.lane_graph), pol.name
        for lane in pol.lane_graph:
            assert lane in pol.lane_nfe, (pol.name, lane)
    # slot axis leads every leaf: policy state migrates with its slot
    for axes in PSTATE_KEY_AXES.values():
        assert axes[0] == "slots"


# -- per-policy invariants (batched == oracle, ledger, lane graph) -----------


@pytest.mark.parametrize("policy", POLICIES)
def test_policy_batched_matches_oracle(policy):
    """Batched serving under each registered policy: ledger conservation,
    monotone lane graph, single compile per bucket, and B=1 eager oracle
    parity for tokens AND per-request NFE ledgers."""
    run_policy_case(
        _reqs(3, policy), [0, 1, 3], max_slots=2, gamma_bar=0.5
    )


@pytest.mark.parametrize("policy", POLICIES)
def test_policy_savings_monotone_in_budget(policy):
    """Realized savings (baseline 2/step minus ledger) are monotone
    non-decreasing in budget: decode is deterministic, so a longer run
    extends the same trajectory and every extra step prices 1 or 2 —
    savings can only accumulate.  Also pins the ledger bounds
    steps <= nfes <= 2*steps for every policy."""
    api, params = toy_serving()
    ec = EngineConfig(scale=1.5, gamma_bar=0.5, max_batch=1)
    rng = np.random.default_rng(11)
    prompt = rng.integers(1, VOCAB, size=5).astype(np.int32)
    prev = -1.0
    for budget in (4, 8, 12):
        req = Request(prompt=prompt, max_new_tokens=budget, policy=policy)
        out = policy_generate(api, params, req, ec)
        steps = budget - 1
        assert steps <= out["nfes"] <= 2 * steps, (policy, out["nfes"])
        saved = 2 * steps - out["nfes"]
        assert saved >= prev, (policy, budget, saved, prev)
        prev = saved


@pytest.mark.parametrize("policy", POLICIES)
def test_policy_crossing_latch_is_monotone(policy):
    """Once a request crosses, it never re-enters a 2-NFE step: the
    oracle's lane trace must be a monotone walk of the policy's lane
    graph (guided* then cond*, never back)."""
    api, params = toy_serving()
    ec = EngineConfig(scale=1.5, gamma_bar=0.2, max_batch=1)
    rng = np.random.default_rng(5)
    req = Request(
        prompt=rng.integers(1, VOCAB, size=4).astype(np.int32),
        max_new_tokens=10, policy=policy,
    )
    out = policy_generate(api, params, req, ec)
    graph = list(get_policy(policy).lane_graph)
    ranks = [graph.index(l) for l in out["lanes"]]
    assert ranks == sorted(ranks), out["lanes"]


def test_policies_mix_in_one_batch():
    """One batch, three different policies: each request still matches
    its own B=1 oracle and the shared ledger conserves."""
    rng = np.random.default_rng(24)
    reqs = [
        Request(prompt=rng.integers(1, VOCAB, size=4).astype(np.int32),
                max_new_tokens=12, policy="compress"),
        Request(prompt=rng.integers(1, VOCAB, size=5).astype(np.int32),
                max_new_tokens=8, policy="online_ag"),
        Request(prompt=rng.integers(1, VOCAB, size=3).astype(np.int32),
                max_new_tokens=6, policy="default"),
    ]
    run_policy_case(reqs, [0, 1, 3], max_slots=2, gamma_bar=0.5)


def test_compress_never_crossing_saves_vs_default():
    """On a never-crossing workload (gamma_bar=2.0) the default ladder
    pays 2 NFEs every step; compress pays 2 only every k-th step —
    the headline 'Compress Guidance' saving, and the fixture for the
    bench's compress >= three-lane acceptance check."""
    api, params = toy_serving()
    ec = EngineConfig(scale=1.5, gamma_bar=2.0, max_batch=1)
    rng = np.random.default_rng(7)
    req = Request(prompt=rng.integers(1, VOCAB, size=4).astype(np.int32),
                  max_new_tokens=13)
    every = CompressGuidance().every
    steps = req.max_new_tokens - 1
    base = policy_generate(api, params, req, ec)
    comp = policy_generate(
        api, params, dataclasses.replace(req, policy="compress"), ec
    )
    assert base["nfes"] == 2 * steps
    assert comp["nfes"] == steps + steps // every
    assert comp["nfes"] < base["nfes"]


def test_online_ag_crosses_without_static_threshold():
    """online_ag ignores the static gamma_bar: with an unreachable
    threshold (2.0) the default policy never truncates, while online_ag
    still crosses once the cond/uncond gap shrinks below rho * gap0."""
    api, params = toy_serving()
    ec = EngineConfig(scale=1.5, gamma_bar=2.0, max_batch=1)
    rng = np.random.default_rng(13)
    req = Request(prompt=rng.integers(1, VOCAB, size=5).astype(np.int32),
                  max_new_tokens=12)
    base = policy_generate(api, params, req, ec)
    onl = policy_generate(
        api, params, dataclasses.replace(req, policy="online_ag"), ec
    )
    assert all(l == "guided" for l in base["lanes"])
    assert "cond" in onl["lanes"], onl["lanes"]
    assert onl["nfes"] < base["nfes"]


def test_non_default_policy_requires_guided_lane():
    from repro.serving import BatcherConfig, EngineConfig, StepBatcher

    api, params = toy_serving()
    bat = StepBatcher(
        api, params, EngineConfig(max_batch=1), BatcherConfig(max_slots=1)
    )
    with pytest.raises(ValueError):
        bat.submit(Request(prompt=np.array([1, 2], np.int32),
                           max_new_tokens=4, guided=False, policy="compress"))
    with pytest.raises(ValueError):
        bat.submit(Request(prompt=np.array([1, 2], np.int32),
                           max_new_tokens=4, policy="unregistered"))


# -- regression: compress completion between two guidance refreshes ----------


@pytest.mark.parametrize("horizon", [1, 4])
def test_compress_completion_between_refreshes(horizon):
    """Satellite fix: a compress request whose budget ends on a *reuse*
    step (between two unconditional refreshes) must free its slot via the
    ``_complete_now`` path with the deferred-uncond ledger intact, and a
    queued request must be able to take the slot.  Crossing mid-period
    (second request, easy threshold) exercises the crossed latch under
    deferred-uncond pricing in the same run."""
    rng = np.random.default_rng(17)
    reqs = [
        # 6 decode steps, refresh at step 3 only -> completes on step 5,
        # a cached-delta reuse step mid-period (every=4)
        Request(prompt=rng.integers(1, VOCAB, size=4).astype(np.int32),
                max_new_tokens=7, gamma_bar=2.0, policy="compress"),
        Request(prompt=rng.integers(1, VOCAB, size=5).astype(np.int32),
                max_new_tokens=6, gamma_bar=0.2, policy="compress"),
        # late arrival: must reuse the slot freed mid-period
        Request(prompt=rng.integers(1, VOCAB, size=3).astype(np.int32),
                max_new_tokens=5, gamma_bar=2.0, policy="compress"),
    ]
    bat, done = run_policy_case(
        reqs, [0, 0, 2], max_slots=2, gamma_bar=0.5, horizon=horizon,
        async_fetch=horizon > 1,
    )
    rep = bat.report()
    recs = rep["requests"]
    # first request: never crossed, completed between refreshes with the
    # compress ledger (6 steps, one refresh -> 7 NFEs, not 12)
    assert recs["0"]["crossed_step"] is None
    assert recs["0"]["nfes"] == 7.0, recs["0"]["nfes"]
    # second request crossed mid-period (latch held under deferred uncond)
    assert recs["1"]["crossed_step"] is not None
    # the late arrival got the freed slot and completed
    assert recs["2"]["tokens_out"] == 5


# -- hypothesis churn, generalized per policy --------------------------------
# Guarded import (not module-level importorskip) so the deterministic half
# of this net still runs where hypothesis isn't installed; CI installs it
# via requirements-dev.txt and executes the properties.

try:
    from hypothesis import given, settings, strategies as st
    _HAS_HYPOTHESIS = True
except ImportError:
    _HAS_HYPOTHESIS = False

if _HAS_HYPOTHESIS:
    settings.register_profile(
        "ci", max_examples=25, deadline=None, derandomize=True)
    settings.register_profile("dev", max_examples=25, deadline=None)
    settings.load_profile(os.environ.get("HYPOTHESIS_PROFILE", "ci"))

# a request: (prompt_len, budget, gamma_bar index, policy index)
_GB = [None, 2.0, -1.0, 0.8]  # engine default / never / immediately / mid


def _spec_requests(specs, seed):
    rng = np.random.default_rng(seed)
    return [
        Request(
            prompt=rng.integers(1, VOCAB, size=plen).astype(np.int32),
            max_new_tokens=budget,
            gamma_bar=_GB[gbi],
            policy=POLICIES[pi],
        )
        for plen, budget, gbi, pi in specs
    ]


def _churn_case(specs, arrivals, max_slots, seed):
    run_policy_case(
        _spec_requests(specs, seed), arrivals[: len(specs)],
        max_slots=max_slots, gamma_bar=0.95,
    )


def _eos_horizon_parity_case(specs, eos, seed):
    from repro.serving import BatcherConfig, StepBatcher
    from tests._toy_lm import toy_coeffs

    api, params = toy_serving()
    ec = EngineConfig(scale=1.5, gamma_bar=0.95, max_batch=2)
    outs = []
    for H in (1, 4):
        bat = StepBatcher(
            api, params, ec,
            BatcherConfig(max_slots=2, horizon=H, eos_token=int(eos),
                          async_fetch=H > 1),
            coeffs=toy_coeffs(),
        )
        reqs = _spec_requests(specs, seed)
        rids = [bat.submit(r, arrival_step=i) for i, r in enumerate(reqs)]
        done = bat.run()
        t = bat.report()["totals"]
        assert t["nfes_device"] == t["nfes_expected"], (
            H, t["nfes_device"], t["nfes_expected"])
        assert t["nfes_device"] == sum(d["nfes"] for d in done.values())
        outs.append({rid: done[rid] for rid in rids})
    for rid in outs[0]:
        np.testing.assert_array_equal(
            outs[0][rid]["tokens"], outs[1][rid]["tokens"],
            err_msg=f"H=1 vs H=4 token drift for request {rid}",
        )
        assert outs[0][rid]["nfes"] == outs[1][rid]["nfes"], rid


if _HAS_HYPOTHESIS:
    # a request: (prompt_len, budget, gamma_bar index, policy index)
    _req = st.tuples(
        st.integers(2, 6),
        st.integers(2, 10),
        st.integers(0, len(_GB) - 1),
        st.integers(0, len(POLICIES) - 1),
    )

    @settings(max_examples=10, deadline=None)
    @given(
        st.lists(_req, min_size=1, max_size=4),
        st.lists(st.integers(0, 6), min_size=4, max_size=4),
        st.integers(1, 3),
        st.integers(0, 2**31 - 1),
    )
    def test_policy_churn_invariants(specs, arrivals, max_slots, seed):
        """Random admission order, budgets, thresholds AND per-request
        policies ⇒ every request completes with its own budget, the NFE
        ledger conserves across all policies sharing the batch, lane
        walks stay monotone on each policy's graph, and every request
        matches its own B=1 oracle (no KV or policy-state bleed between
        slots)."""
        _churn_case(specs, arrivals, max_slots, seed)

    @settings(max_examples=5, deadline=None)
    @given(
        st.lists(_req, min_size=1, max_size=3),
        st.integers(0, VOCAB - 1),
        st.integers(0, 2**31 - 1),
    )
    def test_policy_churn_with_eos_horizon_parity(specs, eos, seed):
        """EOS churn: with a random EOS token cutting budgets short, the
        horizon-fused run (H=4, async) must stay token- and
        ledger-identical to the H=1 run for every policy mix — early
        slot frees cannot bleed KV or policy state into the replacement
        request."""
        _eos_horizon_parity_case(specs, eos, seed)

else:
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_policy_churn_invariants():
        pass

    @pytest.mark.skip(reason="hypothesis not installed")
    def test_policy_churn_with_eos_horizon_parity():
        pass


def test_policy_churn_fixed_example():
    """One pinned churn example (all three policies, staggered arrivals)
    so the property's invariant executes even where hypothesis is
    unavailable."""
    _churn_case(
        [(4, 9, 0, 1), (3, 5, 1, 2), (5, 7, 3, 0), (2, 4, 2, 1)],
        [0, 1, 1, 4], 2, 123,
    )


def test_policy_eos_horizon_parity_fixed_example():
    """Pinned EOS churn example: H=1 == H=4 tokens/ledgers with an EOS
    token that fires inside the toy vocabulary."""
    _eos_horizon_parity_case([(4, 10, 0, 1), (3, 8, 1, 2)], eos=5, seed=9)
