"""Deterministic three-lane ladder invariants on the toy LM (fast path).

The hypothesis suite in tests/test_properties.py drives the same helper
(`tests/_toy_lm.run_ladder_case`) with *random* admission orders, budgets
and crossing thresholds; this file pins a set of hand-picked adversarial
cases so the invariants are exercised even where hypothesis is not
installed (it is importorskip'd there)."""
import numpy as np

from repro.serving import EngineConfig, Request, linear_ag_generate
from tests._toy_lm import VOCAB, run_ladder_case, toy_coeffs, toy_serving


def _p(rng, n):
    return rng.integers(1, VOCAB, size=n).astype(np.int32)


def test_full_ladder_mixed_churn():
    """Linear, never-crossing-linear, plain-guided and unguided requests
    with late arrivals through 2 slots: all ladder invariants hold and the
    full guided -> linear -> cond path is taken."""
    rng = np.random.default_rng(0)
    reqs = [
        Request(prompt=_p(rng, 4), max_new_tokens=9, linear=True),
        Request(prompt=_p(rng, 5), max_new_tokens=6),
        Request(prompt=_p(rng, 3), max_new_tokens=12, linear=True, gamma_bar=2.0),
        Request(prompt=_p(rng, 4), max_new_tokens=5, guided=False),
        Request(prompt=_p(rng, 4), max_new_tokens=7, linear=True),
    ]
    bat, done = run_ladder_case(
        reqs, [0, 0, 2, 3, 5], max_slots=2, gamma_bar=0.95
    )
    histories = [bat.lane_history[r] for r in done]
    assert ["guided", "linear", "cond"] in histories, histories
    assert ["guided", "linear"] in histories, histories  # quality-pinned
    assert ["cond"] in histories  # unguided admitted straight to cond


def test_single_slot_serializes_ladder():
    """max_slots=1 forces strict slot reuse across every lane."""
    rng = np.random.default_rng(1)
    reqs = [
        Request(prompt=_p(rng, 4), max_new_tokens=8, linear=True),
        Request(prompt=_p(rng, 4), max_new_tokens=8, linear=True, gamma_bar=2.0),
        Request(prompt=_p(rng, 3), max_new_tokens=4, guided=False),
    ]
    run_ladder_case(reqs, [0, 0, 0], max_slots=1, gamma_bar=0.95)


def test_immediate_crossing_skips_linear_lane():
    """gamma_bar=-1 crosses on the first decode step — before the K-step
    warmup completes — so a linear-opted request legally skips the linear
    lane (guided -> cond) and the ladder stays monotone."""
    rng = np.random.default_rng(2)
    reqs = [Request(prompt=_p(rng, 4), max_new_tokens=6, linear=True, gamma_bar=-1.0)]
    bat, done = run_ladder_case(reqs, [0], max_slots=1, gamma_bar=0.95)
    (rid,) = done
    assert bat.lane_history[rid] == ["guided", "cond"]


def test_budget_inside_warmup_never_leaves_guided():
    """A budget shorter than the warmup window completes in the guided lane."""
    rng = np.random.default_rng(3)
    K = toy_coeffs().K
    reqs = [
        Request(
            prompt=_p(rng, 4), max_new_tokens=K, linear=True, gamma_bar=2.0
        )
    ]
    bat, done = run_ladder_case(reqs, [0], max_slots=1, gamma_bar=0.95)
    (rid,) = done
    assert bat.lane_history[rid] == ["guided"]
    assert done[rid]["nfes"] == 2 * (K - 1)


def test_oracle_lane_trace_matches_batcher_history():
    """The eager oracle's per-step lane labels compress to exactly the
    batcher's lane_history at B=1."""
    api, params = toy_serving()
    coeffs = toy_coeffs()
    rng = np.random.default_rng(4)
    r = Request(prompt=_p(rng, 5), max_new_tokens=10, linear=True)
    ec = EngineConfig(scale=1.5, gamma_bar=0.95, max_batch=1)
    ora = linear_ag_generate(api, params, r, ec, coeffs)
    compressed = [ora["lanes"][0]]
    for lane in ora["lanes"][1:]:
        if lane != compressed[-1]:
            compressed.append(lane)
    bat, done = run_ladder_case([r], [0], max_slots=1, gamma_bar=0.95)
    (rid,) = done
    assert bat.lane_history[rid] == compressed
    assert done[rid]["nfes"] == ora["nfes"]
    rep = bat.report()["totals"]
    assert rep["extrapolated_uncond"] == ora["linear_steps"]
