"""End-to-end behaviour: the paper's pipeline on a trained tiny DiT.

Trains a small conditional DiT for a few dozen steps, then checks the
paper's qualitative claims hold end to end:
  * AG with gamma_bar just below 1 saves NFEs and stays close to CFG (SSIM)
  * AG dominates naive step reduction at matched NFEs
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import policy as pol
from repro.core.adaptive import ag_sample
from repro.data.synthetic import ImageDataset
from repro.diffusion.sampler import dit_eps_model, sample_with_policy
from repro.diffusion.schedule import cosine_schedule
from repro.diffusion.solvers import get_solver
from repro.metrics.ssim import ssim
from repro.models import build
from repro.training.optim import adamw
from repro.training.train_loop import make_dit_train_step


@pytest.fixture(scope="module")
def trained():
    cfg = get_config("ldm-dit").reduced()
    api = build(cfg)
    params = api.init(jax.random.PRNGKey(0))
    sched = cosine_schedule(100)
    ds = ImageDataset(
        num_classes=cfg.vocab_size, channels=cfg.latent_ch, hw=cfg.latent_hw
    )
    opt = adamw(lr=2e-3)
    st = opt.init(params)
    step = make_dit_train_step(api, sched, opt)
    key = jax.random.PRNGKey(1)
    for _ in range(40):
        key, k1, k2 = jax.random.split(key, 3)
        x0, cond = ds.sample(k1, 16)
        params, st, _ = step(params, st, {"x0": x0, "cond": cond}, k2)
    return cfg, api, params, sched


def test_ag_close_to_cfg_with_fewer_nfes(trained):
    cfg, api, params, sched = trained
    model = dit_eps_model(api)
    solver = get_solver("dpmpp_2m", sched)
    steps, scale = 10, 4.0
    key = jax.random.PRNGKey(2)
    x_T = jax.random.normal(key, (4, cfg.latent_ch, cfg.latent_hw, cfg.latent_hw))
    cond = jnp.arange(4, dtype=jnp.int32)
    x_cfg, _ = sample_with_policy(
        model, params, solver, pol.cfg_policy(steps, scale), x_T, cond
    )
    x_ag, info = ag_sample(model, params, solver, steps, scale, 0.95, x_T, cond)
    nfes = float(np.mean(np.asarray(info["nfes"])))
    assert nfes < 2 * steps  # actually saved something
    s = float(np.mean(np.asarray(ssim(x_ag, x_cfg))))
    assert s > 0.8, (s, nfes)


def test_ag_beats_naive_step_reduction(trained):
    """Fig. 5's claim at one operating point: AG truncation replicates the
    20-NFE baseline better than CFG with fewer steps at equal NFEs."""
    cfg, api, params, sched = trained
    model = dit_eps_model(api)
    solver = get_solver("dpmpp_2m", sched)
    steps, scale = 10, 4.0
    key = jax.random.PRNGKey(3)
    x_T = jax.random.normal(key, (4, cfg.latent_ch, cfg.latent_hw, cfg.latent_hw))
    cond = (jnp.arange(4) % cfg.vocab_size).astype(jnp.int32)
    baseline, _ = sample_with_policy(
        model, params, solver, pol.cfg_policy(steps, scale), x_T, cond
    )
    # AG at 15 NFEs: 5 CFG + 5 cond
    x_ag, _ = sample_with_policy(
        model, params, solver, pol.ag_policy(steps, scale, truncate_at=5), x_T, cond
    )
    # naive: 7 CFG steps ~ 14 NFEs (one less; favourable to naive)
    naive, _ = sample_with_policy(
        model, params, solver, pol.cfg_policy(7, scale), x_T, cond
    )
    s_ag = float(np.mean(np.asarray(ssim(x_ag, baseline))))
    s_naive = float(np.mean(np.asarray(ssim(naive, baseline))))
    assert s_ag > s_naive, (s_ag, s_naive)
