"""Guidance algebra (Eq. 3 / 7 / 9)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.guidance import (
    cfg_combine,
    cfg_combine_with_gamma,
    cosine_similarity,
    pix2pix_combine,
)


def test_cfg_combine_endpoints(key):
    u = jax.random.normal(key, (2, 8))
    c = jax.random.normal(jax.random.PRNGKey(1), (2, 8))
    np.testing.assert_allclose(cfg_combine(u, c, 1.0), c, rtol=1e-6)
    np.testing.assert_allclose(cfg_combine(u, c, 0.0), u, rtol=1e-6)


def test_cfg_combine_affine(key):
    u = jax.random.normal(key, (2, 8))
    c = jax.random.normal(jax.random.PRNGKey(1), (2, 8))
    s = 7.5
    out = cfg_combine(u, c, s)
    np.testing.assert_allclose(out, u + s * (c - u), rtol=1e-5)


def test_cfg_combine_per_sample_scale(key):
    u = jax.random.normal(key, (3, 4, 4))
    c = jax.random.normal(jax.random.PRNGKey(1), (3, 4, 4))
    s = jnp.asarray([0.0, 1.0, 2.0])
    out = cfg_combine(u, c, s)
    np.testing.assert_allclose(out[0], u[0], rtol=1e-5)
    np.testing.assert_allclose(out[1], c[1], rtol=1e-5)


def test_cosine_similarity_bounds_and_identity(key):
    a = jax.random.normal(key, (4, 32))
    g = cosine_similarity(a, a)
    np.testing.assert_allclose(g, 1.0, atol=1e-5)
    g2 = cosine_similarity(a, -a)
    np.testing.assert_allclose(g2, -1.0, atol=1e-5)


def test_pix2pix_reduces_to_cfg(key):
    """With s_image = 1 and eps_ui == eps_uu the 3-term form reduces to Eq 3."""
    uu = jax.random.normal(key, (2, 16))
    ci = jax.random.normal(jax.random.PRNGKey(1), (2, 16))
    out = pix2pix_combine(uu, uu, ci, s_text=7.5, s_image=1.0)
    np.testing.assert_allclose(out, cfg_combine(uu, ci, 7.5), rtol=1e-5)


def test_combine_with_gamma_matches_parts(key):
    u = jax.random.normal(key, (2, 64))
    c = jax.random.normal(jax.random.PRNGKey(1), (2, 64))
    out, gamma = cfg_combine_with_gamma(u, c, 3.0)
    np.testing.assert_allclose(out, cfg_combine(u, c, 3.0))
    np.testing.assert_allclose(gamma, cosine_similarity(c, u), rtol=1e-6)
