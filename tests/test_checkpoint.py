"""Checkpoint round-trip over nested dict/list pytrees."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.training import checkpoint


def test_roundtrip(tmp_path, key):
    tree = {
        "a": {"w": jax.random.normal(key, (4, 4)), "b": jnp.zeros((2,), jnp.bfloat16)},
        "blocks": [{"k": jnp.arange(3)}, {"k": jnp.arange(3) * 2}],
    }
    path = str(tmp_path / "ck.npz")
    checkpoint.save(path, tree)
    like = jax.tree.map(jnp.zeros_like, tree)
    back = checkpoint.load(path, like)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        np.testing.assert_allclose(np.asarray(a, np.float32), np.asarray(b, np.float32))
        assert a.dtype == b.dtype
