"""Golden serving fixtures: seeded workloads + their expected outputs.

``tests/test_golden.py`` locks the engine and both batcher variants
bit-exactly (tokens, NFE ledgers, lifecycle steps) against
``tests/fixtures/golden_serving.json`` so refactors cannot silently drift
the decode path.  Regenerate deliberately after an *intended* numerical
change:

    PYTHONPATH=src python tests/make_golden.py

The three-lane case stores the fitted window coefficients IN the fixture
(rather than refitting at test time) so the lock is independent of the
test host's LAPACK solve.
"""
from __future__ import annotations

import functools
import json
import os

import numpy as np

FIXTURE = os.path.join(os.path.dirname(__file__), "fixtures", "golden_serving.json")


@functools.lru_cache(maxsize=1)
def golden_model():
    import jax

    from repro.configs import get_config
    from repro.models import build

    cfg = get_config("llama3.2-1b").reduced()
    api = build(cfg)
    params = api.init(jax.random.PRNGKey(0))
    return cfg, api, params


def _prompts(seed, lens):
    cfg, _, _ = golden_model()
    rng = np.random.default_rng(seed)
    return [rng.integers(1, cfg.vocab_size, size=n).astype(np.int32) for n in lens]


def run_engine_case(mesh=None):
    """Whole-batch engine: token AND score (gamma) trajectories.  ``mesh``
    runs the identical batch sharded (tests/test_sharded_serving.py asserts
    token/NFE bit-equality against the meshless fixture)."""
    from repro.serving import EngineConfig, GuidedEngine, Request

    cfg, api, params = golden_model()
    p = _prompts(21, [6, 5, 4])
    reqs = [
        Request(prompt=p[0], max_new_tokens=8),
        Request(prompt=p[1], max_new_tokens=8, negative_prompt=p[2]),
    ]
    ec = EngineConfig(scale=1.5, gamma_bar=0.0, max_batch=2)
    out = GuidedEngine(api, params, ec, mesh=mesh).generate(reqs)
    return {
        "tokens": out["tokens"].tolist(),
        "nfes": out["nfes"].tolist(),
        "gammas": np.asarray(out["gammas"], np.float64).tolist(),
    }


def _batcher_record(bat, done, rids):
    rep = bat.report()["requests"]
    return {
        str(rid): {
            "tokens": done[rid]["tokens"].tolist(),
            "nfes": done[rid]["nfes"],
            "lane_history": bat.lane_history[rid],
            "admit_step": rep[str(rid)]["admit_step"],
            "crossed_step": rep[str(rid)]["crossed_step"],
            "linear_step": rep[str(rid)]["linear_step"],
            "migrated_step": rep[str(rid)]["migrated_step"],
            "complete_step": rep[str(rid)]["complete_step"],
        }
        for rid in rids
    }


def run_batcher_case(mesh=None, horizon=1, obs=None, paged=False):
    """Two-lane churn under a fixed seed: late arrival, slot reuse, a
    never-crossing neighbour, plain traffic.  ``mesh`` runs the identical
    workload sharded (tests/test_sharded_serving.py asserts bit-equality
    against the fixture generated without one); ``horizon`` fuses H decode
    substeps per dispatch (tokens/NFE ledgers must still match the fixture
    bit-exactly — lifecycle steps quantize to horizon boundaries);
    ``paged`` serves from the paged KV pool (DESIGN.md §15 — same bit-exact
    contract, compile counts excluded)."""
    from repro.serving import BatcherConfig, EngineConfig, Request, StepBatcher

    cfg, api, params = golden_model()
    p = _prompts(22, [6, 5, 6, 4])
    reqs = [
        Request(prompt=p[0], max_new_tokens=8),
        Request(prompt=p[1], max_new_tokens=6),
        Request(prompt=p[2], max_new_tokens=5, gamma_bar=2.0),
        Request(prompt=p[3], max_new_tokens=4, guided=False),
    ]
    ec = EngineConfig(scale=1.5, gamma_bar=0.0, max_batch=2)
    bat = StepBatcher(
        api, params, ec,
        BatcherConfig(max_slots=2, buckets=(1, 2), horizon=horizon,
                      paged=paged, page_size=4),
        mesh=mesh, obs=obs,
    )
    rids = [bat.submit(r, arrival_step=a) for r, a in zip(reqs, [0, 0, 2, 4])]
    done = bat.run()
    return {
        "requests": _batcher_record(bat, done, rids),
        "compile_counts": bat.compile_counts,
    }


def fit_golden_coeffs():
    """Fit the three-lane case's window coefficients (generation time only;
    the fixture stores the vector so test hosts never re-solve)."""
    from repro.core.linear_ag import fit_ols_window
    from repro.serving import EngineConfig, Request, collect_cfg_logit_histories

    cfg, api, params = golden_model()
    p = _prompts(20, [6, 5])
    fit_reqs = [Request(prompt=q, max_new_tokens=10) for q in p]
    eps_c, eps_u = collect_cfg_logit_histories(
        api, params, fit_reqs, EngineConfig(scale=1.5, gamma_bar=2.0)
    )
    coeffs, _ = fit_ols_window(eps_c, eps_u, K=2)
    return coeffs


def run_three_lane_case(coeffs, mesh=None, horizon=1, obs=None, paged=False):
    """Three-lane churn: full ladder, never-crossing linear request, slot
    reuse — driven by the FIXTURE's coefficient vector.  ``mesh`` runs the
    identical workload sharded, ``horizon`` fuses H substeps per dispatch,
    ``paged`` serves from the paged KV pool (see ``run_batcher_case``)."""
    from repro.serving import BatcherConfig, EngineConfig, Request, StepBatcher

    cfg, api, params = golden_model()
    p = _prompts(23, [6, 5, 6])
    reqs = [
        Request(prompt=p[0], max_new_tokens=12, linear=True),
        Request(prompt=p[1], max_new_tokens=8, linear=True, gamma_bar=2.0),
        Request(prompt=p[2], max_new_tokens=6),
    ]
    ec = EngineConfig(scale=1.5, gamma_bar=0.5, max_batch=2)
    bat = StepBatcher(
        api, params, ec,
        BatcherConfig(max_slots=2, buckets=(1, 2), horizon=horizon,
                      paged=paged, page_size=4),
        coeffs=coeffs, mesh=mesh, obs=obs,
    )
    rids = [bat.submit(r, arrival_step=a) for r, a in zip(reqs, [0, 1, 3])]
    done = bat.run()
    t = bat.report()["totals"]
    return {
        "requests": _batcher_record(bat, done, rids),
        "compile_counts": bat.compile_counts,
        "lane_steps": t["lane_steps"],
        "nfes_device": t["nfes_device"],
    }


def run_policy_case(policy, mesh=None, horizon=1, obs=None, paged=False):
    """Per-policy churn under a fixed seed: one instant-crosser, one
    never-crossing request (``gamma_bar=2.0``, exercising compress's
    refresh cadence / online_ag's gap watermark to the end of its budget)
    and a short late arrival forcing slot reuse.  Stored per policy id
    under ``fixture["policies"]`` and locked by test_golden.py."""
    from repro.serving import BatcherConfig, EngineConfig, Request, StepBatcher

    cfg, api, params = golden_model()
    p = _prompts(24, [6, 5, 4])
    reqs = [
        Request(prompt=p[0], max_new_tokens=12, policy=policy),
        Request(prompt=p[1], max_new_tokens=8, gamma_bar=2.0, policy=policy),
        Request(prompt=p[2], max_new_tokens=6, policy=policy),
    ]
    ec = EngineConfig(scale=1.5, gamma_bar=0.5, max_batch=2)
    bat = StepBatcher(
        api, params, ec,
        BatcherConfig(max_slots=2, buckets=(1, 2), horizon=horizon,
                      paged=paged, page_size=4),
        mesh=mesh, obs=obs,
    )
    rids = [bat.submit(r, arrival_step=a) for r, a in zip(reqs, [0, 1, 3])]
    done = bat.run()
    t = bat.report()["totals"]
    return {
        "requests": _batcher_record(bat, done, rids),
        "lane_steps": t["lane_steps"],
        "nfes_device": t["nfes_device"],
    }


def main(argv=None):
    import argparse

    from repro.core.policies import policy_names

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument(
        "--policy", choices=list(policy_names()), default=None,
        help="regenerate only this policy's fixture section "
             "(default: regenerate everything)",
    )
    args = ap.parse_args(argv)

    fixture = {}
    if os.path.exists(FIXTURE):
        with open(FIXTURE) as f:
            fixture = json.load(f)

    if args.policy is None:
        coeffs = fit_golden_coeffs()
        fixture.update(
            engine=run_engine_case(),
            batcher=run_batcher_case(),
            coeffs={"K": coeffs.K, "beta": coeffs.beta.tolist()},
            three_lane=run_three_lane_case(coeffs),
        )
        policies = list(policy_names())
    else:
        policies = [args.policy]
    fixture.setdefault("policies", {})
    for pid in policies:
        fixture["policies"][pid] = run_policy_case(pid)

    os.makedirs(os.path.dirname(FIXTURE), exist_ok=True)
    with open(FIXTURE, "w") as f:
        json.dump(fixture, f, indent=2, sort_keys=True)
    print(f"wrote {FIXTURE} (policies: {', '.join(policies)})")


if __name__ == "__main__":
    main()
