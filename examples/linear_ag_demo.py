"""LinearAG (section 5.1): replace unconditional NFEs with OLS predictions.

Stores CFG trajectories, fits the per-step scalar regressions of Eq. 8,
then samples with the Eq. 11 policy and compares against the naive
CFG/conditional alternation at equal NFEs.

Run:  PYTHONPATH=src python examples/linear_ag_demo.py
"""
import argparse
import sys

sys.path.insert(0, "src")
sys.path.insert(0, ".")  # benchmarks/ lives at the repo root

import jax
import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--scale", type=float, default=4.0)
    ap.add_argument("--train-trajs", type=int, default=6)
    args = ap.parse_args()

    from benchmarks.common import N_CLASSES, get_trained_dit
    from benchmarks.bench_ols import collect
    from repro.core import policy as pol
    from repro.core.linear_ag import fit_ols, linear_ag_sample
    from repro.diffusion.sampler import dit_eps_model, sample_with_policy
    from repro.diffusion.solvers import get_solver
    from repro.metrics.ssim import ssim

    cfg, api, params, sched = get_trained_dit()
    model = dit_eps_model(api)
    solver = get_solver("dpmpp_2m", sched)
    S, sc = args.steps, args.scale

    print("== collect CFG trajectories + fit per-step OLS (Eq. 8) ==")
    eps_c, eps_u = collect(model, params, solver, S, sc, args.train_trajs, 8,
                           jax.random.PRNGKey(0), cfg)
    coeffs, train_mse = fit_ols(eps_c, eps_u)
    print(f"  per-step train MSE: {np.array2string(train_mse, precision=5)}")

    k1, k2 = jax.random.split(jax.random.PRNGKey(1))
    x_T = jax.random.normal(k1, (8, cfg.latent_ch, cfg.latent_hw, cfg.latent_hw))
    cond = jax.random.randint(k2, (8,), 0, N_CLASSES)
    baseline, _ = sample_with_policy(
        model, params, solver, pol.cfg_policy(S, sc), x_T, cond
    )

    print("== LinearAG sampling (Eq. 11) ==")
    x_lag, info = linear_ag_sample(model, params, solver, S, sc, coeffs, x_T, cond)
    s_lag = float(np.mean(np.asarray(ssim(x_lag, baseline))))
    print(f"  NFEs {info['nfe']} (CFG: {2 * S}), SSIM vs baseline {s_lag:.4f}")

    x_alt, _ = sample_with_policy(
        model, params, solver, pol.alternating_policy(S, sc), x_T, cond
    )
    s_alt = float(np.mean(np.asarray(ssim(x_alt, baseline))))
    print(f"  naive alternation ({pol.alternating_policy(S, sc).nfes()} NFEs): SSIM {s_alt:.4f}")
    print(f"  => LinearAG {'captures path regularity (wins)' if s_lag > s_alt else 'did not beat naive here'}")


if __name__ == "__main__":
    main()
