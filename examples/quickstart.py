"""Quickstart: the paper's pipeline end to end on one machine.

1. Trains a small class-conditioned DiT (the LDM-512 stand-in) on the
   synthetic conditioned dataset for a few hundred steps.
2. Samples with full CFG (the 2T-NFE baseline).
3. Samples with Adaptive Guidance at gamma_bar and reports NFE savings +
   SSIM fidelity to the baseline, vs naive step reduction at matched NFEs.

Run:  PYTHONPATH=src python examples/quickstart.py [--train-steps 600]
"""
import argparse
import sys

sys.path.insert(0, "src")
sys.path.insert(0, ".")  # benchmarks/ lives at the repo root

import jax
import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--train-steps", type=int, default=1000)
    ap.add_argument("--sample-steps", type=int, default=20)
    ap.add_argument("--scale", type=float, default=4.0)
    ap.add_argument("--gamma-bar", type=float, default=None,
                    help="default: calibrated from a CFG probe pass")
    ap.add_argument("--batch", type=int, default=8)
    args = ap.parse_args()

    import os

    os.environ.setdefault("REPRO_DIT_STEPS", str(args.train_steps))
    from benchmarks.common import N_CLASSES, get_trained_dit
    from repro.core import policy as pol
    from repro.core.adaptive import ag_sample, ag_sample_jit, calibrate_gamma_bar
    from repro.diffusion.sampler import dit_eps_model, sample_with_policy
    from repro.diffusion.solvers import get_solver
    from repro.metrics.ssim import ssim

    print("== 1. train (or load cached) conditional DiT ==")
    cfg, api, params, sched = get_trained_dit(steps=args.train_steps)
    model = dit_eps_model(api)
    solver = get_solver("dpmpp_2m", sched)

    key = jax.random.PRNGKey(0)
    k1, k2 = jax.random.split(key)
    x_T = jax.random.normal(
        k1, (args.batch, cfg.latent_ch, cfg.latent_hw, cfg.latent_hw)
    )
    cond = jax.random.randint(k2, (args.batch,), 0, N_CLASSES)

    print("== 2. CFG baseline ==")
    S, sc = args.sample_steps, args.scale
    baseline, _ = sample_with_policy(
        model, params, solver, pol.cfg_policy(S, sc), x_T, cond
    )
    print(f"  CFG: {2 * S} NFEs")

    print("== 3. Adaptive Guidance ==")
    gamma_bar = args.gamma_bar
    if gamma_bar is None:
        gamma_bar = calibrate_gamma_bar(model, params, solver, S, sc, x_T, cond)
        print(f"  calibrated gamma_bar = {gamma_bar:.6f}")
    x_ag, info = ag_sample(
        model, params, solver, S, sc, gamma_bar, x_T, cond, collect_gammas=True
    )
    nfes = np.asarray(info["nfes"])
    s_ag = np.asarray(ssim(x_ag, baseline))
    print(f"  AG(gamma_bar={gamma_bar:.6f}): NFEs {nfes.mean():.1f} +- {nfes.std():.1f}"
          f"  (saves {100 * (1 - nfes.mean() / (2 * S)):.0f}%)")
    print(f"  SSIM vs baseline: {s_ag.mean():.4f} +- {s_ag.std():.4f}")
    g = np.asarray(info["gammas"]).mean(1)
    print(f"  gamma trace: {np.array2string(g, precision=3)}")

    print("== 4. naive step reduction at matched NFEs ==")
    n_matched = max(2, int(round(nfes.mean())) // 2)
    naive, _ = sample_with_policy(
        model, params, solver, pol.cfg_policy(n_matched, sc), x_T, cond
    )
    s_nv = np.asarray(ssim(naive, baseline))
    print(f"  CFG-{n_matched}-steps ({2 * n_matched} NFEs): SSIM {s_nv.mean():.4f}")
    verdict = "AG wins" if s_ag.mean() > s_nv.mean() else "naive wins (unexpected!)"
    print(f"  => {verdict}")

    print("== 5. compiled two-phase AG (TPU execution path) ==")
    x_jit, ij = ag_sample_jit(model, params, solver, S, sc, gamma_bar, x_T, cond)
    print(f"  guided steps: {int(ij['guided_steps'])}, NFEs match eager: "
          f"{bool(np.allclose(np.asarray(ij['nfes']), nfes))}")


if __name__ == "__main__":
    main()
