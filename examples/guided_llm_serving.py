"""Serve a small LM with classifier-free-guided decoding + Adaptive Guidance.

Demonstrates the paper's mechanism on the assigned text architectures:
batched requests, per-request NFE ledgers, negative prompts, and the AG
guided->conditional phase switch — first with the whole-batch engine, then
under churn with the step-level continuous batcher (staggered arrivals,
mixed budgets, lane migration, telemetry; DESIGN.md §7).

Run:  PYTHONPATH=src python examples/guided_llm_serving.py [--arch llama3.2-1b]
"""
import argparse
import sys

sys.path.insert(0, "src")
sys.path.insert(0, ".")  # benchmarks/ lives at the repo root

import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--train-steps", type=int, default=300)
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--scale", type=float, default=1.5)
    ap.add_argument("--gamma-bar", type=float, default=0.9)
    args = ap.parse_args()

    import os

    os.environ.setdefault("REPRO_LM_STEPS", str(args.train_steps))
    from benchmarks.common import get_trained_lm
    from repro.serving.engine import EngineConfig, GuidedEngine, Request

    print(f"== train (or load cached) reduced {args.arch} ==")
    cfg, api, params = get_trained_lm(steps=args.train_steps, arch=args.arch)

    rng = np.random.default_rng(0)
    reqs = [
        Request(prompt=rng.integers(1, cfg.vocab_size, size=8).astype(np.int32),
                max_new_tokens=args.max_new),
        Request(prompt=rng.integers(1, cfg.vocab_size, size=6).astype(np.int32),
                max_new_tokens=args.max_new,
                negative_prompt=rng.integers(1, cfg.vocab_size, size=4).astype(
                    np.int32
                )),
    ]

    print("== full CFG decoding (2 NFEs / step) ==")
    eng_cfg = GuidedEngine(
        api, params, EngineConfig(scale=args.scale, gamma_bar=1.1, max_batch=4)
    )
    out_cfg = eng_cfg.generate(reqs)
    print(f"  NFEs: {out_cfg['nfes']}")

    print(f"== Adaptive Guidance (gamma_bar={args.gamma_bar}) ==")
    eng = GuidedEngine(
        api, params,
        EngineConfig(scale=args.scale, gamma_bar=args.gamma_bar, max_batch=4),
    )
    out = eng.generate(reqs)
    agree = float(np.mean(out["tokens"] == out_cfg["tokens"]))
    print(f"  NFEs: {out['nfes']} (CFG: {out_cfg['nfes']})")
    for i in range(len(reqs)):
        sav = 100 * (1 - out["nfes"][i] / out_cfg["nfes"][i])
        neg = " (with negative prompt)" if reqs[i].negative_prompt is not None else ""
        print(f"  req {i}: saved {sav:.0f}% NFEs{neg}")
    print(f"  guided steps: {out['guided_steps']} / {args.max_new - 1}")
    print(f"  top-1 agreement with CFG decode: {agree:.3f}")
    print(f"  mean gamma per guided step: {np.round(out['gammas'].mean(1), 3)}")

    print("== step-level continuous batching under churn ==")
    from repro.serving import BatcherConfig, StepBatcher

    bat = StepBatcher(
        api, params,
        EngineConfig(scale=args.scale, gamma_bar=args.gamma_bar, max_batch=4),
        BatcherConfig(max_slots=4),
    )
    churn = [
        Request(prompt=rng.integers(1, cfg.vocab_size, size=8).astype(np.int32),
                max_new_tokens=args.max_new),
        Request(prompt=rng.integers(1, cfg.vocab_size, size=6).astype(np.int32),
                max_new_tokens=args.max_new // 2),  # short budget
        Request(prompt=rng.integers(1, cfg.vocab_size, size=6).astype(np.int32),
                max_new_tokens=args.max_new, gamma_bar=2.0),  # never truncates
        Request(prompt=rng.integers(1, cfg.vocab_size, size=7).astype(np.int32),
                max_new_tokens=args.max_new, guided=False),  # plain traffic
    ]
    for i, r in enumerate(churn):
        bat.submit(r, arrival_step=3 * i)  # staggered arrivals
    done = bat.run()
    rep = bat.report()
    t = rep["totals"]
    for rid in sorted(done):
        rec = rep["requests"][str(rid)]
        lane = "plain" if not rec["guided"] else (
            f"migrated@{rec['migrated_step']}" if rec["migrated_step"] is not None
            else "guided throughout"
        )
        print(f"  req {rid}: {rec['tokens_out']} tokens, {rec['nfes']:.0f} NFEs "
              f"(saved {rec['savings_pct']:.0f}%), {lane}")
    print(f"  fleet: {t['mean_savings_pct']:.1f}% NFEs saved vs always-CFG, "
          f"{t['tokens_per_sec']:.1f} tok/s, "
          f"step p50 {t['step_latency_ms']['p50']:.1f} ms, "
          f"ledger {t['nfes_device']:.0f}=={t['nfes_expected']:.0f}")


if __name__ == "__main__":
    main()
