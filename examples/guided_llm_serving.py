"""Serve a small LM with classifier-free-guided decoding + Adaptive Guidance.

Demonstrates the paper's mechanism on the assigned text architectures:
batched requests, per-request NFE ledgers, negative prompts, and the AG
guided->conditional phase switch.

Run:  PYTHONPATH=src python examples/guided_llm_serving.py [--arch llama3.2-1b]
"""
import argparse
import sys

sys.path.insert(0, "src")
sys.path.insert(0, ".")  # benchmarks/ lives at the repo root

import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--train-steps", type=int, default=300)
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--scale", type=float, default=1.5)
    ap.add_argument("--gamma-bar", type=float, default=0.9)
    args = ap.parse_args()

    import os

    os.environ.setdefault("REPRO_LM_STEPS", str(args.train_steps))
    from benchmarks.common import get_trained_lm
    from repro.serving.engine import EngineConfig, GuidedEngine, Request

    print(f"== train (or load cached) reduced {args.arch} ==")
    cfg, api, params = get_trained_lm(steps=args.train_steps, arch=args.arch)

    rng = np.random.default_rng(0)
    reqs = [
        Request(prompt=rng.integers(1, cfg.vocab_size, size=8).astype(np.int32),
                max_new_tokens=args.max_new),
        Request(prompt=rng.integers(1, cfg.vocab_size, size=6).astype(np.int32),
                max_new_tokens=args.max_new,
                negative_prompt=rng.integers(1, cfg.vocab_size, size=4).astype(np.int32)),
    ]

    print("== full CFG decoding (2 NFEs / step) ==")
    eng_cfg = GuidedEngine(api, params, EngineConfig(scale=args.scale, gamma_bar=1.1, max_batch=4))
    out_cfg = eng_cfg.generate(reqs)
    print(f"  NFEs: {out_cfg['nfes']}")

    print(f"== Adaptive Guidance (gamma_bar={args.gamma_bar}) ==")
    eng = GuidedEngine(api, params, EngineConfig(scale=args.scale, gamma_bar=args.gamma_bar, max_batch=4))
    out = eng.generate(reqs)
    agree = float(np.mean(out["tokens"] == out_cfg["tokens"]))
    print(f"  NFEs: {out['nfes']} (CFG: {out_cfg['nfes']})")
    for i in range(len(reqs)):
        sav = 100 * (1 - out["nfes"][i] / out_cfg["nfes"][i])
        neg = " (with negative prompt)" if reqs[i].negative_prompt is not None else ""
        print(f"  req {i}: saved {sav:.0f}% NFEs{neg}")
    print(f"  guided steps: {out['guided_steps']} / {args.max_new - 1}")
    print(f"  top-1 agreement with CFG decode: {agree:.3f}")
    print(f"  mean gamma per guided step: {np.round(out['gammas'].mean(1), 3)}")


if __name__ == "__main__":
    main()
