"""Gradient-based guidance-policy search (section 4) — the DARTS pipeline.

Generates teacher noise->image pairs with the CFG baseline, relaxes the
per-step guidance choice with soft alphas (Eq. 5), optimizes Eq. 6 with
Lion, and hardens the result into a discrete policy.

Run:  PYTHONPATH=src python examples/policy_search.py [--steps 10 --epochs 4]
"""
import argparse
import sys

sys.path.insert(0, "src")
sys.path.insert(0, ".")  # benchmarks/ lives at the repo root

import jax
import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=10)
    ap.add_argument("--scale", type=float, default=4.0)
    ap.add_argument("--epochs", type=int, default=4)
    ap.add_argument("--pairs", type=int, default=16)
    args = ap.parse_args()

    from benchmarks.common import N_CLASSES, get_trained_dit
    from repro.core import nas, policy as pol
    from repro.data.synthetic import make_noise_image_pairs
    from repro.diffusion.sampler import dit_eps_model
    from repro.diffusion.solvers import get_solver

    cfg, api, params, sched = get_trained_dit()
    model = dit_eps_model(api)
    solver = get_solver("dpmpp_2m", sched)

    print("== generate teacher pairs (CFG baseline) ==")
    dataset = make_noise_image_pairs(
        jax.random.PRNGKey(0), model, params, solver, args.steps, args.scale,
        args.pairs, 4, N_CLASSES, (cfg.latent_ch, cfg.latent_hw, cfg.latent_hw),
    )

    print("== DARTS search over guidance options ==")
    space = nas.SearchSpace(
        steps=args.steps, scales=(args.scale / 2, args.scale, 2 * args.scale)
    )
    alpha, history = nas.search(
        model, params, solver, space, dataset, jax.random.PRNGKey(1),
        epochs=args.epochs, lr=5e-2,
    )
    for h in history:
        print(f"  epoch {h['epoch']}: loss={h['loss']:.4f} dist={h['dist']:.4f} cost={h['cost']:.1f}")

    w = np.asarray(jax.nn.softmax(alpha, axis=-1))
    print("== per-step option weights [uncond, cond, cfg/2, cfg, cfg*2] ==")
    for i in range(args.steps):
        print(f"  step {i:2d}: {np.round(w[i], 3)}")
    hard = pol.from_alpha(np.asarray(alpha), space.scales, args.scale)
    print(f"== hardened policy ({hard.nfes()} NFEs vs {2 * args.steps} CFG) ==")
    print("  " + hard.describe())


if __name__ == "__main__":
    main()
